"""Pure-jnp oracle for the parity8 kernels — delegates to repro.core.parity8."""
from __future__ import annotations

import jax

from repro.core import parity8 as _p


def encode(data: jax.Array) -> jax.Array:
    """(N, D) uint32, D % 64 == 0 -> (N, D//64) packed parity bytes."""
    return _p.encode_lines_packed(data)


def check(data: jax.Array, parity: jax.Array) -> jax.Array:
    """(N, D), (N, D//64) -> per-line status (N, D//16)."""
    return _p.check_lines_packed(data, parity)

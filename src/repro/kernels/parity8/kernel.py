"""Pallas TPU kernels for 8-bit-per-line parity (detection-only mode).

XOR-fold of 16 words per 64B line — ~1.1 VPU ops/byte, entirely memory
bound. Same streaming BlockSpec structure as the SECDED kernels; encode and
check are one-pass so detection costs a single HBM read of the data.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import pick_block, use_interpret

DEFAULT_BLOCK_ROWS = 32
WORDS_PER_LINE = 16


def _line_parity(data: jax.Array) -> jax.Array:
    """(BR, D) -> (BR, D/16) parity bytes."""
    lines = data.reshape(data.shape[0], data.shape[1] // WORDS_PER_LINE,
                         WORDS_PER_LINE)
    folded = lines[..., 0]
    for i in range(1, WORDS_PER_LINE):
        folded = folded ^ lines[..., i]
    folded = folded ^ (folded >> 16)
    folded = folded ^ (folded >> 8)
    return folded & jnp.uint32(0xFF)


def _pack4(codes: jax.Array) -> jax.Array:
    g = codes.reshape(codes.shape[0], codes.shape[1] // 4, 4)
    return (g[..., 0] | (g[..., 1] << 8) | (g[..., 2] << 16)
            | (g[..., 3] << 24)).astype(jnp.uint32)


def _encode_kernel(data_ref, parity_ref):
    parity_ref[...] = _pack4(_line_parity(data_ref[...]))


def _check_kernel(data_ref, parity_ref, status_ref):
    expected = _line_parity(data_ref[...])
    packed = parity_ref[...]
    parts = [(packed >> (8 * j)) & jnp.uint32(0xFF) for j in range(4)]
    stored = jnp.stack(parts, axis=-1).reshape(expected.shape)
    status_ref[...] = jnp.where(expected == stored, 0, 1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def encode(data: jax.Array, block_rows: int = DEFAULT_BLOCK_ROWS) -> jax.Array:
    """(N, D) uint32 (D % 64 == 0) -> (N, D//64) packed parity bytes."""
    n, d = data.shape
    br = pick_block(n, block_rows)
    return pl.pallas_call(
        _encode_kernel,
        grid=(n // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, d // 64), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d // 64), jnp.uint32),
        interpret=use_interpret(),
    )(data)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def check(data: jax.Array, parity: jax.Array,
          block_rows: int = DEFAULT_BLOCK_ROWS) -> jax.Array:
    """(N, D), (N, D//64) -> per-line status (N, D//16): 0 ok, 1 corrupt."""
    n, d = data.shape
    br = pick_block(n, block_rows)
    return pl.pallas_call(
        _check_kernel,
        grid=(n // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((br, d // 64), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, d // 16), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d // 16), jnp.int32),
        interpret=use_interpret(),
    )(data, parity)

"""Public entry points for parity8 with kernel/ref dispatch."""
from __future__ import annotations

import jax

from repro.kernels.parity8 import kernel, ref


def encode(data: jax.Array, use_kernel: bool = True) -> jax.Array:
    return kernel.encode(data) if use_kernel else ref.encode(data)


def check(data: jax.Array, parity: jax.Array, use_kernel: bool = True
          ) -> jax.Array:
    return kernel.check(data, parity) if use_kernel else ref.check(data, parity)

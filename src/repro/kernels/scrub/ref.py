"""Pure-jnp oracle for the fused scrub kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import secded
from repro.core.layouts import CODE_LANE, DATA_LANES


def scrub_rows(storage: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Decode+correct SECDED rows. (R, 9, W) -> (storage', status (R, 4W))."""
    R, _, W = storage.shape
    data = storage[:, :DATA_LANES, :].reshape(R, -1)
    codes = storage[:, CODE_LANE, :]
    data2, codes2, status = secded.decode_block(data, codes)
    out = jnp.concatenate(
        [data2.reshape(R, DATA_LANES, W), codes2[:, None, :]], axis=1)
    return out, status

"""Pallas TPU kernel: fused scrub sweep (decode + correct + census) in one pass.

A scrub pass over an unfused pipeline costs 3 HBM round-trips (read, decode
status write, corrected write-back). This kernel fuses the whole sweep: one
(BR, 9, W) pool tile in, corrected tile + per-beat status out — the minimum
possible traffic for a repairing scrub (read + write). With the default
BR=16 the VMEM working set is 16 × 9KB × 2 + status ≈ 0.5MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.layouts import CODE_LANE, DATA_LANES
from repro.kernels.common import pick_block, use_interpret
from repro.kernels.secded.kernel import (_encode_beats, _pack4,
                                         _syndrome_action, _unpack4)

DEFAULT_BLOCK_ROWS = 16


def _scrub_kernel(storage_ref, out_ref, status_ref):
    block = storage_ref[...]                       # (BR, 9, W)
    br, _, w = block.shape
    data = block[:, :DATA_LANES, :].reshape(br, DATA_LANES * w)
    pairs = data.reshape(br, data.shape[1] // 2, 2)
    lo, hi = pairs[..., 0], pairs[..., 1]
    stored = _unpack4(block[:, CODE_LANE, :], lo.shape[1])

    syndrome = (_encode_beats(lo, hi) ^ stored) & jnp.uint32(0xFF)
    action = _syndrome_action(syndrome)
    is_data = (action >= 0) & (action < 64)
    is_code = action >= 64
    bit = jnp.where(action >= 0, action, 0).astype(jnp.uint32)
    lo = lo ^ jnp.where(is_data & (bit < 32), jnp.uint32(1) << (bit & 31), 0)
    hi = hi ^ jnp.where(is_data & (bit >= 32), jnp.uint32(1) << (bit & 31), 0)
    stored = stored ^ jnp.where(is_code, jnp.uint32(1) << ((bit - 64) & 7), 0)

    fixed = jnp.stack([lo, hi], axis=-1).reshape(br, DATA_LANES, w)
    out_ref[...] = jnp.concatenate(
        [fixed, _pack4(stored)[:, None, :]], axis=1)
    status_ref[...] = jnp.where(
        action == -1, 0,
        jnp.where(is_data, 1, jnp.where(is_code, 2, 3))).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def scrub_rows(storage: jax.Array, block_rows: int = DEFAULT_BLOCK_ROWS
               ) -> tuple[jax.Array, jax.Array]:
    """(R, 9, W) SECDED rows -> (corrected storage, per-beat status (R, 4W))."""
    R, lanes, W = storage.shape
    br = pick_block(R, block_rows)
    beats = DATA_LANES * W // 2
    return pl.pallas_call(
        _scrub_kernel,
        grid=(R // br,),
        in_specs=[pl.BlockSpec((br, lanes, W), lambda i: (i, 0, 0))],
        out_specs=[pl.BlockSpec((br, lanes, W), lambda i: (i, 0, 0)),
                   pl.BlockSpec((br, beats), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((R, lanes, W), jnp.uint32),
                   jax.ShapeDtypeStruct((R, beats), jnp.int32)],
        interpret=use_interpret(),
    )(storage)

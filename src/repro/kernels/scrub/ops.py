"""Public entry points for the fused scrub kernel, plus the pool adapter."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.secded import DETECTED_UNCORRECTABLE
from repro.kernels.scrub import kernel, ref


def scrub_rows(storage: jax.Array, use_kernel: bool = True
               ) -> tuple[jax.Array, jax.Array]:
    if use_kernel:
        return kernel.scrub_rows(storage)
    return ref.scrub_rows(storage)


def scrub_secded(storage: jax.Array, start: int, stop: int | None = None
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Adapter matching repro.core.scrubber's internal signature.

    Scrubs rows [start, stop) of a pool buffer (stop defaults to R, the
    whole tail); returns (storage', status, row_bad).
    """
    if stop is None:
        stop = storage.shape[0]
    region = storage[start:stop]
    fixed, status = scrub_rows(region)
    storage = storage.at[start:stop].set(fixed)
    row_bad = jnp.max(status, axis=-1) == DETECTED_UNCORRECTABLE
    return storage, status, row_bad

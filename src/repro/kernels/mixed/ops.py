"""Public entry point for the fused mixed-pool read with kernel/ref dispatch.

``use_kernel=None`` (the default) auto-selects: the Pallas kernel when it
lowers natively (TPU), the vectorised jnp oracle — which *is* the engine's
fast path — under interpret mode, where a per-slice grid walk would be pure
overhead.
"""
from __future__ import annotations

import jax

from repro.core.layouts import Layout
from repro.core.pool import PoolState
from repro.kernels.common import use_interpret
from repro.kernels.mixed import kernel, ref


def read_correct(storage: jax.Array, pages: jax.Array, layout: Layout,
                 num_rows: int, boundary: int,
                 use_kernel: bool | None = None) -> jax.Array:
    if use_kernel is None:
        use_kernel = not use_interpret()
    if use_kernel:
        return kernel.read_correct(storage, pages, layout, num_rows, boundary)
    return ref.read_correct(storage, pages, layout, num_rows, boundary)


def read_pool(state: PoolState, pages: jax.Array,
              use_kernel: bool | None = None) -> jax.Array:
    """Convenience wrapper taking a :class:`PoolState`."""
    return read_correct(state.storage, pages, state.layout, state.num_rows,
                        state.boundary, use_kernel=use_kernel)

"""Public entry point for the fused mixed-pool read with kernel/ref dispatch.

``use_kernel=None`` (the default) auto-selects: the Pallas kernel when it
lowers natively (TPU), the vectorised jnp oracle — which *is* the engine's
fast path — under interpret mode, where a per-slice grid walk would be pure
overhead.
"""
from __future__ import annotations

import jax

from repro.core.layouts import Layout
from repro.core.pool import PoolState
from repro.kernels.common import use_interpret
from repro.kernels.mixed import kernel, ref


def read_correct(storage: jax.Array, pages: jax.Array, layout: Layout,
                 num_rows: int, boundary: int,
                 use_kernel: bool | None = None) -> jax.Array:
    if use_kernel is None:
        use_kernel = not use_interpret()
    if use_kernel:
        return kernel.read_correct(storage, pages, layout, num_rows, boundary)
    return ref.read_correct(storage, pages, layout, num_rows, boundary)


def read_correct_routed(storage: jax.Array, pages: jax.Array, layout: Layout,
                        num_rows: int, boundary: int, num_shards: int,
                        shard_id: jax.Array,
                        use_kernel: bool | None = None) -> jax.Array:
    """Router-fused shard-local read of *global* page ids, one pass.

    ``storage`` is one shard's ``(R_local, 9, W)`` slice; rows not owned by
    ``shard_id`` return zeroed, so a ``psum`` over the ``banks`` axis
    assembles the replicated batch (see
    :func:`repro.shard.pool.read`). Kernel/oracle dispatch mirrors
    :func:`read_correct`.
    """
    if use_kernel is None:
        use_kernel = not use_interpret()
    fn = kernel.read_correct_routed if use_kernel else ref.read_correct_routed
    return fn(storage, pages, layout, num_rows, boundary, num_shards,
              shard_id)


def read_pool(state: PoolState, pages: jax.Array,
              use_kernel: bool | None = None) -> jax.Array:
    """Convenience wrapper taking a :class:`PoolState`."""
    return read_correct(state.storage, pages, state.layout, state.num_rows,
                        state.boundary, use_kernel=use_kernel)

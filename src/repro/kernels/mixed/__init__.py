"""Fused mixed-pool page read: universal gather + masked SECDED correction."""
from repro.kernels.mixed.ops import read_correct  # noqa: F401

"""Pallas TPU kernel for the fused mixed-pool page read.

Extends ``repro.kernels.interwrap``'s scalar-prefetch pattern from the pure
InterWrap pool to *any* boundary: the BlockSpec index map performs the
universal coordinate translation of :func:`repro.core.layouts.page_coords`
— SECDED rows, CREAM regular pages under every layout, and reclaimed extra
pages — and the kernel body fuses the Hsiao SECDED check+correct for the
slices that need it, so a mixed batch is one pass over HBM:

  * grid = (n_pages, 8 slices); the page-id vector and a per-page
    ``is_secded`` mask are scalar-prefetched (the paged-attention pattern),
  * the storage BlockSpec fetches slice k of page i straight from its
    physical (row, lane) home — the paper's §4.3 bridge-chip translation
    for mixed layouts as a pure index map,
  * a second BlockSpec streams the matching ``W/8``-word sub-range of the
    page's code plane (each W-word slice covers an exact code sub-range,
    as in ``repro.kernels.migrate``); non-SECDED pages fetch a clamped
    dummy block whose decode result is masked off,
  * the VPU decode (popcount syndromes + select-chain action table, shared
    with ``repro.kernels.secded``) corrects in VMEM before write-back — no
    second pass, no host round-trip.

Layout, boundary, and geometry are static (they live in pool metadata), so
each pool mode compiles once and page ids stay fully dynamic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.layouts import (CODE_LANE, DATA_LANES, GROUP_ROWS, LANES,
                                Layout, extra_base_row)
from repro.kernels.common import use_interpret
from repro.kernels.secded.kernel import decode_correct_block


def _coords(page, k, layout: Layout, num_rows: int, boundary: int,
            ebase: int):
    """Universal translation for slice k of `page` (traced scalars).

    Mirrors :func:`repro.core.layouts.page_coords` one (page, k) at a time —
    ``layout``/``boundary``/``ebase`` are static, so the branch structure
    resolves at trace time.
    """
    is_extra = page >= num_rows
    e = page - num_rows
    if layout == Layout.INTERWRAP:
        is_sec = jnp.logical_and(page >= boundary, page < num_rows)
        group = jnp.where(is_extra, e, page // GROUP_ROWS)
        slot = jnp.where(is_extra, GROUP_ROWS, page % GROUP_ROWS)
        linear = 8 * slot + k
        row = jnp.where(is_sec, page, GROUP_ROWS * group + linear // LANES)
        lane = jnp.where(is_sec, k, linear % LANES)
        return row, lane
    row = jnp.where(is_extra, ebase + GROUP_ROWS * e + k, page)
    lane = jnp.where(is_extra, CODE_LANE, k)
    return row, lane


def _route(page, num_rows: int, num_shards: int):
    """Shard-router translation for one traced global page id.

    Mirrors :func:`repro.shard.router.route` one scalar at a time —
    round-robin striping, extras routed by their extra index. Static
    ``num_rows`` (global) and ``num_shards`` resolve at trace time.
    """
    rows_local = num_rows // num_shards
    is_extra = page >= num_rows
    e = page - num_rows
    shard = jnp.where(is_extra, e % num_shards, page % num_shards)
    local = jnp.where(is_extra, rows_local + e // num_shards,
                      page // num_shards)
    return shard, local


def _read_correct_kernel(pages_ref, is_sec_ref, storage_ref, codes_ref,
                         out_ref):
    i = pl.program_id(0)
    blk = storage_ref[...]                                # (1, 1, W)
    fixed = decode_correct_block(blk, codes_ref[...])
    out_ref[...] = jnp.where(is_sec_ref[i] != 0, fixed, blk)


@functools.partial(jax.jit,
                   static_argnames=("layout", "num_rows", "boundary"))
def read_correct(storage: jax.Array, pages: jax.Array, layout: Layout,
                 num_rows: int, boundary: int) -> jax.Array:
    """(R, 9, W) pool, (n,) int32 page ids -> (n, 8W) corrected page data."""
    n = pages.shape[0]
    W = storage.shape[2]
    ebase = extra_base_row(layout, boundary, W)

    def storage_index(i, k, pages_ref, sec_ref):
        row, lane = _coords(pages_ref[i], k, layout, num_rows, boundary,
                            ebase)
        return row, lane, 0

    def codes_index(i, k, pages_ref, sec_ref):
        # SECDED codes live at (page, CODE_LANE); non-SECDED pages fetch a
        # clamped in-range block that the kernel masks off.
        return jnp.clip(pages_ref[i], 0, num_rows - 1), CODE_LANE, k

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n, DATA_LANES),
        in_specs=[pl.BlockSpec((1, 1, W), storage_index),
                  pl.BlockSpec((1, 1, W // 8), codes_index)],
        out_specs=pl.BlockSpec((1, 1, W), lambda i, k, p, s: (i, k, 0)),
    )
    is_sec = ((pages >= boundary) & (pages < num_rows)).astype(jnp.int32)
    out = pl.pallas_call(
        _read_correct_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, DATA_LANES, W), jnp.uint32),
        interpret=use_interpret(),
    )(pages.astype(jnp.int32), is_sec, storage, storage)
    return out.reshape(n, DATA_LANES * W)


def _read_routed_kernel(pages_ref, flags_ref, sid_ref, storage_ref,
                        codes_ref, out_ref):
    # flags: 0 = not owned by this shard (zeroed), 1 = owned non-SECDED,
    # 2 = owned SECDED (decode-correct)
    i = pl.program_id(0)
    blk = storage_ref[...]                                # (1, 1, W)
    fixed = decode_correct_block(blk, codes_ref[...])
    f = flags_ref[i]
    out = jnp.where(f == 2, fixed, blk)
    out_ref[...] = jnp.where(f == 0, jnp.zeros_like(out), out)


@functools.partial(jax.jit,
                   static_argnames=("layout", "num_rows", "boundary",
                                    "num_shards"))
def read_correct_routed(storage: jax.Array, pages: jax.Array, layout: Layout,
                        num_rows: int, boundary: int, num_shards: int,
                        shard_id: jax.Array) -> jax.Array:
    """Router-fused shard-local read: ONE pass from global ids to page data.

    ``storage`` is one shard's ``(R_local, 9, W)`` slice, ``pages`` are
    ``(n,)`` *global* ids, ``num_rows`` / ``boundary`` the *global*
    geometry. The BlockSpec index map composes the shard router's
    global-id -> (shard, local) translation with the universal layout
    translation of :func:`_coords`, so the two-pass
    route-then-read chain collapses into the scalar-prefetch index map —
    no separate translation dispatch, no per-shard full-batch replication.
    Rows not owned by ``shard_id`` (a traced int32 scalar, typically
    ``jax.lax.axis_index``) fetch a clamped dummy block and come back
    zeroed, so a cross-shard ``psum`` assembles the replicated result.
    Returns ``(n, 8W)`` uint32.
    """
    n = pages.shape[0]
    W = storage.shape[2]
    rows_local = num_rows // num_shards
    boundary_local = boundary // num_shards
    ebase = extra_base_row(layout, boundary_local, W)
    pages = pages.astype(jnp.int32)
    sid = jnp.asarray(shard_id, jnp.int32).reshape(1)

    def storage_index(i, k, pages_ref, flags_ref, sid_ref):
        shard, local = _route(pages_ref[i], num_rows, num_shards)
        local = jnp.where(shard == sid_ref[0], local, 0)
        row, lane = _coords(local, k, layout, rows_local, boundary_local,
                            ebase)
        return row, lane, 0

    def codes_index(i, k, pages_ref, flags_ref, sid_ref):
        shard, local = _route(pages_ref[i], num_rows, num_shards)
        local = jnp.where(shard == sid_ref[0], local, 0)
        return jnp.clip(local, 0, rows_local - 1), CODE_LANE, k

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n, DATA_LANES),
        in_specs=[pl.BlockSpec((1, 1, W), storage_index),
                  pl.BlockSpec((1, 1, W // 8), codes_index)],
        out_specs=pl.BlockSpec((1, 1, W), lambda i, k, p, f, s: (i, k, 0)),
    )
    # region is shard-invariant (global region == local region), so the
    # owned/SECDED flags vectorise outside the grid walk
    shard_v, local_v = _route(pages, num_rows, num_shards)
    owned = shard_v == sid[0]
    is_sec = (local_v >= boundary_local) & (local_v < rows_local)
    flags = jnp.where(owned, jnp.where(is_sec, 2, 1), 0).astype(jnp.int32)
    out = pl.pallas_call(
        _read_routed_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, DATA_LANES, W), jnp.uint32),
        interpret=use_interpret(),
    )(pages, flags, sid, storage, storage)
    return out.reshape(n, DATA_LANES * W)

"""Pure-jnp oracle for the fused mixed-pool read.

Exactly the data path of :func:`repro.core.pool.read_pages_any` (which is
built on the same :func:`repro.core.layouts.page_coords` translation), minus
the parity *status* side channel — parity is detection-only and never alters
the returned data, so the fused read's contract is data-only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import secded
from repro.core.layouts import (CODE_LANE, REGION_SECDED, Layout, page_coords)


def read_correct(storage: jax.Array, pages: jax.Array, layout: Layout,
                 num_rows: int, boundary: int) -> jax.Array:
    """(R, 9, W) pool, (n,) page ids -> (n, 8W) decode-corrected page data."""
    n = pages.shape[0]
    rows, lanes, region = page_coords(layout, num_rows, boundary, pages,
                                      storage.shape[2])
    data = storage[rows, lanes, :].reshape(n, -1)
    if boundary < num_rows:
        crow = jnp.clip(pages, boundary, num_rows - 1)
        fixed, _, _ = secded.decode_block(data, storage[crow, CODE_LANE, :])
        data = jnp.where((region == REGION_SECDED)[:, None], fixed, data)
    return data

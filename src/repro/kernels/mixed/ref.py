"""Pure-jnp oracle for the fused mixed-pool read.

Exactly the data path of :func:`repro.core.pool.read_pages_any` (which is
built on the same :func:`repro.core.layouts.page_coords` translation), minus
the parity *status* side channel — parity is detection-only and never alters
the returned data, so the fused read's contract is data-only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import secded
from repro.core.layouts import (CODE_LANE, REGION_SECDED, Layout, page_coords)


def read_correct(storage: jax.Array, pages: jax.Array, layout: Layout,
                 num_rows: int, boundary: int) -> jax.Array:
    """(R, 9, W) pool, (n,) page ids -> (n, 8W) decode-corrected page data."""
    n = pages.shape[0]
    rows, lanes, region = page_coords(layout, num_rows, boundary, pages,
                                      storage.shape[2])
    data = storage[rows, lanes, :].reshape(n, -1)
    if boundary < num_rows:
        crow = jnp.clip(pages, boundary, num_rows - 1)
        fixed, _, _ = secded.decode_block(data, storage[crow, CODE_LANE, :])
        data = jnp.where((region == REGION_SECDED)[:, None], fixed, data)
    return data


def read_correct_routed(storage: jax.Array, pages: jax.Array, layout: Layout,
                        num_rows: int, boundary: int, num_shards: int,
                        shard_id: jax.Array) -> jax.Array:
    """Unfused two-pass oracle for the router-fused shard-local read.

    Pass 1 is the shard router's global-id -> (shard, local) translation
    (:func:`repro.shard.router.route`); pass 2 the plain mixed-pool read of
    the owned local ids against the shard's *local* geometry. Non-owned
    rows come back zeroed, matching the kernel's psum-ready contract.
    ``storage`` is one shard's ``(R_local, 9, W)`` slice; ``num_rows`` /
    ``boundary`` are the *global* geometry.
    """
    from repro.shard import router
    shard, local = router.route(pages, num_rows, num_shards)
    owned = shard == jnp.asarray(shard_id, jnp.int32)
    data = read_correct(storage, jnp.where(owned, local, 0), layout,
                        num_rows // num_shards, boundary // num_shards)
    return jnp.where(owned[:, None], data, 0)

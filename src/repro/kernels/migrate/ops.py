"""Public entry point for the migration gather/re-encode with dispatch.

``use_kernel=None`` (the default) auto-selects: the Pallas kernel where it
lowers natively (TPU), the vectorised jnp oracle under interpret mode —
where a per-slice grid walk would be pure overhead.
"""
from __future__ import annotations

import jax

from repro.kernels.common import use_interpret
from repro.kernels.migrate import kernel, ref


def gather_encode(storage: jax.Array, pages: jax.Array, num_rows: int,
                  use_kernel: bool | None = None
                  ) -> tuple[jax.Array, jax.Array]:
    if use_kernel is None:
        use_kernel = not use_interpret()
    if use_kernel:
        return kernel.gather_encode(storage, pages, num_rows)
    return ref.gather_encode(storage, pages, num_rows)

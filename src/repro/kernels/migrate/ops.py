"""Public entry point for the migration gather/re-encode with dispatch."""
from __future__ import annotations

import jax

from repro.kernels.migrate import kernel, ref


def gather_encode(storage: jax.Array, pages: jax.Array, num_rows: int,
                  use_kernel: bool = True) -> tuple[jax.Array, jax.Array]:
    if use_kernel:
        return kernel.gather_encode(storage, pages, num_rows)
    return ref.gather_encode(storage, pages, num_rows)

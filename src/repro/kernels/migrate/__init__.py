"""Batched page-migration kernels: InterWrap gather fused with SECDED encode."""
from repro.kernels.migrate import kernel, ops, ref  # noqa: F401

"""Pallas TPU kernel for live page migration: gather + SECDED re-encode, fused.

A protection *upgrade* (boundary shrinks, SECDED region grows) evicts extra
pages whose storage lived in reclaimed code lanes. The VM's migration engine
relocates them into SECDED frames instead of dropping them — which needs, per
page: (1) the bridge-chip wrap gather of its 8 (row, lane) slices and (2) the
Hsiao code plane for its new SECDED home. Doing these as two passes would
stream each page HBM→VMEM→HBM→VMEM; this kernel fuses them so every slice is
touched once:

  * grid = (n_pages, 8 slices), page ids scalar-prefetched (the same
    paged-attention pattern as ``repro.kernels.interwrap``);
  * the storage BlockSpec index map performs the paper's §4.1.3 translation
    ℓ = 8·slot + k, lane = ℓ mod 9, row = 8·group + ℓ div 9;
  * the code output is computed per slice: with W % 8 == 0 each W-word slice
    covers an exact sub-range of the page's packed code plane (W/2 beats →
    W/8 packed code words), so encode needs no cross-slice state.

Outputs land in migration order — ready for a batched scatter into the
destination pool's rows and code lane.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.layouts import GROUP_ROWS, LANES
from repro.kernels.common import use_interpret
from repro.kernels.secded.kernel import _encode_beats


def _coords(page, k, num_rows: int):
    """Bridge-chip translation for slice k of logical `page` (traced scalars)."""
    is_extra = page >= num_rows
    e = page - num_rows
    group = jnp.where(is_extra, e, page // GROUP_ROWS)
    slot = jnp.where(is_extra, GROUP_ROWS, page % GROUP_ROWS)
    linear = 8 * slot + k
    return GROUP_ROWS * group + linear // LANES, linear % LANES


def _gather_encode_kernel(pages_ref, storage_ref, data_ref, codes_ref):
    blk = storage_ref[...]                       # (1, 1, W)
    data_ref[...] = blk
    flat = blk.reshape(1, -1)
    pairs = flat.reshape(1, flat.shape[1] // 2, 2)
    code = _encode_beats(pairs[..., 0], pairs[..., 1])   # (1, W/2) bytes
    g = code.reshape(1, code.shape[1] // 4, 4)
    packed = (g[..., 0] | (g[..., 1] << 8) | (g[..., 2] << 16)
              | (g[..., 3] << 24)).astype(jnp.uint32)
    codes_ref[...] = packed.reshape(codes_ref.shape)


@functools.partial(jax.jit, static_argnames=("num_rows",))
def gather_encode(storage: jax.Array, pages: jax.Array, num_rows: int
                  ) -> tuple[jax.Array, jax.Array]:
    """(R, 9, W) InterWrap pool, (n,) page ids -> (data (n, 8W), codes (n, W)).

    ``codes`` is the packed SECDED plane for each page's future conventional
    row (what ``secded.encode_block`` would produce over ``data``).
    """
    n = pages.shape[0]
    W = storage.shape[2]

    def storage_index(i, k, pages_ref):
        row, lane = _coords(pages_ref[i], k, num_rows)
        return row, lane, 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n, 8),
        in_specs=[pl.BlockSpec((1, 1, W), storage_index)],
        out_specs=[pl.BlockSpec((1, 1, W), lambda i, k, pages_ref: (i, k, 0)),
                   pl.BlockSpec((1, 1, W // 8),
                                lambda i, k, pages_ref: (i, k, 0))],
    )
    data, codes = pl.pallas_call(
        _gather_encode_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n, 8, W), jnp.uint32),
                   jax.ShapeDtypeStruct((n, 8, W // 8), jnp.uint32)],
        interpret=use_interpret(),
    )(pages.astype(jnp.int32), storage)
    return data.reshape(n, 8 * W), codes.reshape(n, W)

"""Pure-jnp oracle for the fused migration gather/re-encode."""
from __future__ import annotations

import functools

import jax

from repro.core import secded
from repro.kernels.interwrap import ref as interwrap_ref


@functools.partial(jax.jit, static_argnames=("num_rows",))
def gather_encode(storage: jax.Array, pages: jax.Array, num_rows: int
                  ) -> tuple[jax.Array, jax.Array]:
    """(R, 9, W), (n,) -> (data (n, 8W), packed SECDED codes (n, W))."""
    data = interwrap_ref.gather(storage, pages, num_rows)
    return data, secded.encode_block(data)

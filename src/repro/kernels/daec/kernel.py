"""Pallas TPU kernels for SEC-DAEC(144,128) encode / decode-correct.

Same mapping as ``repro.kernels.secded`` — pure VPU work, memory-bound,
one (BLOCK_ROWS, D) tile streamed HBM→VMEM per grid step — but every
128-bit superbeat runs TWO Hsiao(72,64) passes over its bit-interleaved
even/odd codewords (see ``repro.core.daec`` for the construction and why
interleaving is what buys adjacent-double correction). The deinterleave /
reinterleave steps are branch-free Morton shuffles (5 shift+mask rounds
each), so the whole decode stays a select-tree + shifts on the VPU: no
gathers, no tables. Code-plane shapes are identical to SECDED's
(``(N, D) -> (N, D//8)``), so the tiling constants carry over unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import pick_block, use_interpret
from repro.kernels.secded.kernel import _encode_beats, _syndrome_action

DEFAULT_BLOCK_ROWS = 32


def _compact_even(x: jax.Array) -> jax.Array:
    """Even bits of a uint32 -> low 16 (Morton compaction, VPU-only)."""
    x = x & jnp.uint32(0x55555555)
    x = (x | (x >> 1)) & jnp.uint32(0x33333333)
    x = (x | (x >> 2)) & jnp.uint32(0x0F0F0F0F)
    x = (x | (x >> 4)) & jnp.uint32(0x00FF00FF)
    x = (x | (x >> 8)) & jnp.uint32(0x0000FFFF)
    return x


def _spread_even(x: jax.Array) -> jax.Array:
    """Low 16 bits -> even positions (inverse Morton)."""
    x = x & jnp.uint32(0x0000FFFF)
    x = (x | (x << 8)) & jnp.uint32(0x00FF00FF)
    x = (x | (x << 4)) & jnp.uint32(0x0F0F0F0F)
    x = (x | (x << 2)) & jnp.uint32(0x33333333)
    x = (x | (x << 1)) & jnp.uint32(0x55555555)
    return x


def _split4(data: jax.Array):
    """(BR, D) -> 4 superbeat word planes (BR, D//4)."""
    g = data.reshape(data.shape[0], data.shape[1] // 4, 4)
    return g[..., 0], g[..., 1], g[..., 2], g[..., 3]


def _merge4(w0, w1, w2, w3, shape):
    return jnp.stack([w0, w1, w2, w3], axis=-1).reshape(shape)


def _deinterleave(w0, w1, w2, w3):
    e = [_compact_even(w) for w in (w0, w1, w2, w3)]
    o = [_compact_even(w >> 1) for w in (w0, w1, w2, w3)]
    return ((e[0] | (e[1] << 16), e[2] | (e[3] << 16)),
            (o[0] | (o[1] << 16), o[2] | (o[3] << 16)))


def _interleave(a_lo, a_hi, b_lo, b_hi):
    m = jnp.uint32(0xFFFF)
    w0 = _spread_even(a_lo & m) | (_spread_even(b_lo & m) << 1)
    w1 = _spread_even(a_lo >> 16) | (_spread_even(b_lo >> 16) << 1)
    w2 = _spread_even(a_hi & m) | (_spread_even(b_hi & m) << 1)
    w3 = _spread_even(a_hi >> 16) | (_spread_even(b_hi >> 16) << 1)
    return w0, w1, w2, w3


def _pack2(fields: jax.Array) -> jax.Array:
    g = fields.reshape(fields.shape[0], fields.shape[1] // 2, 2)
    return (g[..., 0] | (g[..., 1] << 16)).astype(jnp.uint32)


def _unpack2(packed: jax.Array, beats: int) -> jax.Array:
    parts = [(packed >> (16 * j)) & jnp.uint32(0xFFFF) for j in range(2)]
    return jnp.stack(parts, axis=-1).reshape(packed.shape[0], beats)


def _encode_fields(w0, w1, w2, w3) -> jax.Array:
    (a_lo, a_hi), (b_lo, b_hi) = _deinterleave(w0, w1, w2, w3)
    return _spread_even(_encode_beats(a_lo, a_hi)) | \
        (_spread_even(_encode_beats(b_lo, b_hi)) << 1)


def _correct_one(lo, hi, code, stored):
    """One Hsiao codeword's fused check+correct (the secded select tree)."""
    syndrome = (code ^ stored) & jnp.uint32(0xFF)
    action = _syndrome_action(syndrome)
    is_data = (action >= 0) & (action < 64)
    is_code = action >= 64
    bit = jnp.where(action >= 0, action, 0).astype(jnp.uint32)
    lo = lo ^ jnp.where(is_data & (bit < 32), jnp.uint32(1) << (bit & 31), 0)
    hi = hi ^ jnp.where(is_data & (bit >= 32), jnp.uint32(1) << (bit & 31), 0)
    stored = stored ^ jnp.where(is_code, jnp.uint32(1) << ((bit - 64) & 7), 0)
    status = jnp.where(
        action == -1, 0,
        jnp.where(is_data, 1, jnp.where(is_code, 2, 3))).astype(jnp.int32)
    return lo, hi, stored, status


def _encode_kernel(data_ref, codes_ref):
    w0, w1, w2, w3 = _split4(data_ref[...])
    codes_ref[...] = _pack2(_encode_fields(w0, w1, w2, w3))


def _decode_kernel(data_ref, codes_ref, out_data_ref, out_codes_ref,
                   status_ref):
    data = data_ref[...]
    w0, w1, w2, w3 = _split4(data)
    fields = _unpack2(codes_ref[...], w0.shape[1])
    (a_lo, a_hi), (b_lo, b_hi) = _deinterleave(w0, w1, w2, w3)
    a_lo, a_hi, code_a, st_a = _correct_one(
        a_lo, a_hi, _encode_beats(a_lo, a_hi), _compact_even(fields))
    b_lo, b_hi, code_b, st_b = _correct_one(
        b_lo, b_hi, _encode_beats(b_lo, b_hi), _compact_even(fields >> 1))
    w0, w1, w2, w3 = _interleave(a_lo, a_hi, b_lo, b_hi)
    out_data_ref[...] = _merge4(w0, w1, w2, w3, data.shape)
    out_codes_ref[...] = _pack2(
        _spread_even(code_a) | (_spread_even(code_b) << 1))
    st = jnp.maximum(st_a, st_b)                   # per superbeat
    status_ref[...] = jnp.stack([st, st], axis=-1).reshape(
        st.shape[0], st.shape[1] * 2)              # broadcast to beats


@functools.partial(jax.jit, static_argnames=("block_rows",))
def encode(data: jax.Array, block_rows: int = DEFAULT_BLOCK_ROWS) -> jax.Array:
    """(N, D) uint32 -> (N, D//8) packed DAEC code fields."""
    n, d = data.shape
    br = pick_block(n, block_rows)
    return pl.pallas_call(
        _encode_kernel,
        grid=(n // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, d // 8), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d // 8), jnp.uint32),
        interpret=use_interpret(),
    )(data)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def decode(data: jax.Array, codes: jax.Array,
           block_rows: int = DEFAULT_BLOCK_ROWS
           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused check+correct. (N,D),(N,D//8) -> (data', codes', status (N,D//2))."""
    n, d = data.shape
    br = pick_block(n, block_rows)
    return pl.pallas_call(
        _decode_kernel,
        grid=(n // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((br, d // 8), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                   pl.BlockSpec((br, d // 8), lambda i: (i, 0)),
                   pl.BlockSpec((br, d // 2), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, d), jnp.uint32),
                   jax.ShapeDtypeStruct((n, d // 8), jnp.uint32),
                   jax.ShapeDtypeStruct((n, d // 2), jnp.int32)],
        interpret=use_interpret(),
    )(data, codes)

"""jnp reference oracle for the DAEC kernels — delegates to the core codec."""
from __future__ import annotations

import jax

from repro.core import daec


def encode(data: jax.Array) -> jax.Array:
    return daec.encode_block(data)


def decode(data: jax.Array, codes: jax.Array
           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    return daec.decode_block(data, codes)

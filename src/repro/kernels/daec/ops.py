"""Dispatch layer: DAEC Pallas kernel vs. jnp reference oracle."""
from __future__ import annotations

import jax

from repro.kernels.daec import kernel, ref


def encode(data: jax.Array, use_kernel: bool = True) -> jax.Array:
    """(N, D) uint32 -> (N, D//8) packed DAEC code fields."""
    if use_kernel:
        return kernel.encode(data)
    return ref.encode(data)


def decode(data: jax.Array, codes: jax.Array, use_kernel: bool = True
           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused check+correct -> (data', codes', status per 64-bit beat)."""
    if use_kernel:
        return kernel.decode(data, codes)
    return ref.decode(data, codes)

"""Pallas TPU kernel fusing batched hash probing with the mixed-pool gather.

The objcache get path as one pass over HBM: instead of resolving keys to
pages on the host (or in a separate device dispatch) and then gathering,
the *BlockSpec index map itself runs the probe* — the scalar-prefetched
slot-key and slot-page arrays are scanned with the canonical bounded linear
probe of :mod:`repro.objcache.hash_index`, and the winning page id feeds the
same universal coordinate translation the ``mixed`` kernel uses. The kernel
body re-runs the (cheap, SMEM-resident) probe to recover the per-query
``is_secded`` bit and fuses the Hsiao SECDED check+correct exactly as
:mod:`repro.kernels.mixed` does:

  * grid = (n_queries, 8 slices); scalar-prefetch: query keys, slot keys,
    slot pages (the paged-attention pattern, with the page table replaced by
    a probed hash table),
  * the storage BlockSpec fetches slice k of the *matched* page straight
    from its physical (row, lane) home — probe and gather fused,
  * the codes BlockSpec streams the matching ``W/8``-word code sub-range;
    non-SECDED and unmatched pages fetch a clamped dummy block that the
    body masks off,
  * unmatched queries resolve to page 0 (callers mask rows on their own
    found bit; the jnp oracle agrees bit-for-bit on those rows).

Geometry, layout, boundary, and the probe window are static; keys and the
index contents stay fully dynamic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.layouts import (CODE_LANE, DATA_LANES, Layout,
                                extra_base_row)
from repro.kernels.common import use_interpret
from repro.kernels.mixed.kernel import _coords
from repro.kernels.secded.kernel import decode_correct_block
from repro.objcache.hash_index import hash_u32


def _probe_page(q, keys_ref, pages_ref, capacity: int, probe: int):
    """Scalar probe of the prefetched index -> (page, found) traced scalars.

    Mirrors :func:`repro.objcache.hash_index.find` one query at a time —
    ``capacity``/``probe`` are static, so the window unrolls at trace time
    into ``probe`` SMEM loads.
    """
    qk = q.astype(jnp.uint32)
    h = (hash_u32(qk) % jnp.uint32(capacity)).astype(jnp.int32)
    slot = jnp.int32(capacity)
    for r in range(probe):
        s = (h + r) % capacity
        hit = (slot == capacity) & (keys_ref[s] == qk)
        slot = jnp.where(hit, s, slot)
    found = slot < capacity
    page = jnp.where(found, pages_ref[jnp.minimum(slot, capacity - 1)], 0)
    return page.astype(jnp.int32), found


def _make_body(capacity: int, probe: int, num_rows: int, boundary: int):
    def body(q_ref, keys_ref, pages_ref, storage_ref, codes_ref, out_ref):
        i = pl.program_id(0)
        page, _ = _probe_page(q_ref[i], keys_ref, pages_ref, capacity, probe)
        is_sec = (page >= boundary) & (page < num_rows)
        blk = storage_ref[...]                            # (1, 1, W)
        fixed = decode_correct_block(blk, codes_ref[...])
        out_ref[...] = jnp.where(is_sec, fixed, blk)
    return body


@functools.partial(jax.jit, static_argnames=("layout", "num_rows",
                                             "boundary", "probe"))
def lookup_read(storage: jax.Array, slot_keys: jax.Array,
                slot_pages: jax.Array, queries: jax.Array, layout: Layout,
                num_rows: int, boundary: int, probe: int) -> jax.Array:
    """(R, 9, W) pool + (C,) index arrays + (n,) keys -> (n, 8W) page data."""
    n = queries.shape[0]
    capacity = slot_keys.shape[0]
    w = storage.shape[2]
    ebase = extra_base_row(layout, boundary, w)

    def storage_index(i, k, q_ref, keys_ref, pages_ref):
        page, _ = _probe_page(q_ref[i], keys_ref, pages_ref, capacity, probe)
        row, lane = _coords(page, k, layout, num_rows, boundary, ebase)
        return row, lane, 0

    def codes_index(i, k, q_ref, keys_ref, pages_ref):
        page, _ = _probe_page(q_ref[i], keys_ref, pages_ref, capacity, probe)
        return jnp.clip(page, 0, num_rows - 1), CODE_LANE, k

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n, DATA_LANES),
        in_specs=[pl.BlockSpec((1, 1, w), storage_index),
                  pl.BlockSpec((1, 1, w // 8), codes_index)],
        out_specs=pl.BlockSpec((1, 1, w), lambda i, k, q, ks, ps: (i, k, 0)),
    )
    out = pl.pallas_call(
        _make_body(capacity, probe, num_rows, boundary),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, DATA_LANES, w), jnp.uint32),
        interpret=use_interpret(),
    )(queries.astype(jnp.uint32), slot_keys.astype(jnp.uint32),
      slot_pages.astype(jnp.int32), storage, storage)
    return out.reshape(n, DATA_LANES * w)

"""Fused hash-probe + mixed-pool page gather kernel (the objcache get path)."""

"""Public entry point for the fused probe+gather with kernel/ref dispatch.

Follows the ``kernels.mixed`` pattern: ``use_kernel=None`` auto-selects the
Pallas kernel where it lowers natively (TPU) and the vectorised jnp oracle
under interpret mode, where a per-slice grid walk would be pure overhead.
"""
from __future__ import annotations

import jax

from repro.core.layouts import Layout
from repro.core.pool import PoolState
from repro.kernels.common import use_interpret
from repro.kernels.hash import kernel, ref
from repro.objcache.hash_index import HashIndex


def lookup_read(storage: jax.Array, slot_keys: jax.Array,
                slot_pages: jax.Array, queries: jax.Array, layout: Layout,
                num_rows: int, boundary: int, probe: int,
                use_kernel: bool | None = None) -> jax.Array:
    if use_kernel is None:
        use_kernel = not use_interpret()
    fn = kernel.lookup_read if use_kernel else ref.lookup_read
    return fn(storage, slot_keys, slot_pages, queries, layout, num_rows,
              boundary, probe)


def lookup_pool(state: PoolState, index: HashIndex, queries: jax.Array,
                use_kernel: bool | None = None) -> jax.Array:
    """Convenience wrapper taking a :class:`PoolState` and :class:`HashIndex`."""
    return lookup_read(state.storage, index.key, index.page, queries,
                       state.layout, state.num_rows, state.boundary,
                       index.probe, use_kernel=use_kernel)

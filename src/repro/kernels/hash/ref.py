"""Pure-jnp oracle for the fused probe + gather read.

Semantics: resolve each query key against the index's slot arrays with the
canonical bounded linear probe (:mod:`repro.objcache.hash_index` is the
single source of the probe sequence), then perform the decode-corrected
mixed-pool gather of the matched pages — exactly
:func:`repro.kernels.mixed.ref.read_correct` over the resolved page vector.
Unmatched queries resolve to page 0; callers mask rows on their own found
bit (the oracle and the kernel agree bit-for-bit on those rows too, both
reading page 0).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.layouts import Layout
from repro.kernels.mixed import ref as mixed_ref
from repro.objcache import hash_index as hix


def resolve_pages(slot_keys: jax.Array, slot_pages: jax.Array,
                  queries: jax.Array, probe: int) -> jax.Array:
    """(C,) keys, (C,) pages, (n,) queries -> (n,) matched pages (0 if absent)."""
    c = slot_keys.shape[0]
    q = queries.astype(jnp.uint32)
    cand = hix.probe_slots(q, c, probe)
    hit = slot_keys[cand] == q[:, None]
    first = jnp.argmax(hit, axis=1)
    found = jnp.any(hit, axis=1)
    slot = jnp.take_along_axis(cand, first[:, None], axis=1)[:, 0]
    return jnp.where(found, slot_pages[slot], 0).astype(jnp.int32)


def lookup_read(storage: jax.Array, slot_keys: jax.Array,
                slot_pages: jax.Array, queries: jax.Array, layout: Layout,
                num_rows: int, boundary: int, probe: int) -> jax.Array:
    """(R, 9, W) pool + index arrays + (n,) keys -> (n, 8W) page data."""
    pages = resolve_pages(slot_keys, slot_pages, queries, probe)
    return mixed_ref.read_correct(storage, pages, layout, num_rows, boundary)

"""Public entry points for InterWrap gather/scatter with kernel/ref dispatch."""
from __future__ import annotations

import jax

from repro.kernels.interwrap import kernel, ref


def gather(storage: jax.Array, pages: jax.Array, num_rows: int,
           use_kernel: bool = True) -> jax.Array:
    if use_kernel:
        return kernel.gather(storage, pages, num_rows)
    return ref.gather(storage, pages, num_rows)


def scatter(storage: jax.Array, pages: jax.Array, data: jax.Array,
            num_rows: int, use_kernel: bool = True) -> jax.Array:
    if use_kernel:
        return kernel.scatter(storage, pages, data, num_rows)
    return ref.scatter(storage, pages, data, num_rows)

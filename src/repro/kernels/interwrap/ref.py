"""Pure-jnp oracle for the InterWrap (Solution 3) gather/scatter."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.layouts import GROUP_ROWS, LANES

_LANES_TBL = np.empty((9, 8), np.int32)
_ROWS_TBL = np.empty((9, 8), np.int32)
for _s in range(9):
    for _k in range(8):
        _linear = 8 * _s + _k
        _LANES_TBL[_s, _k] = _linear % LANES
        _ROWS_TBL[_s, _k] = _linear // LANES


def wrap_coords(pages: jax.Array, num_rows: int
                ) -> tuple[jax.Array, jax.Array]:
    """(n,) page ids -> (rows (n,8), lanes (n,8)) under inter-bank wrap-around."""
    is_extra = pages >= num_rows
    e = pages - num_rows
    group = jnp.where(is_extra, e, pages // GROUP_ROWS)
    slot = jnp.where(is_extra, GROUP_ROWS, pages % GROUP_ROWS)
    lanes = jnp.asarray(_LANES_TBL)[slot]
    rows = GROUP_ROWS * group[:, None] + jnp.asarray(_ROWS_TBL)[slot]
    return rows, lanes


def gather(storage: jax.Array, pages: jax.Array, num_rows: int) -> jax.Array:
    """(R,9,W), (n,) -> (n, 8W): read n wrap-striped pages."""
    rows, lanes = wrap_coords(pages, num_rows)
    return storage[rows, lanes, :].reshape(pages.shape[0], -1)


def scatter(storage: jax.Array, pages: jax.Array, data: jax.Array,
            num_rows: int) -> jax.Array:
    """Write n wrap-striped pages; data (n, 8W) -> updated storage."""
    rows, lanes = wrap_coords(pages, num_rows)
    chunks = data.astype(jnp.uint32).reshape(pages.shape[0], 8, -1)
    return storage.at[rows, lanes, :].set(chunks)

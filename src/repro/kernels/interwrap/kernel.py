"""Pallas TPU kernel for the InterWrap (Solution 3) page gather/scatter.

This is the paper's bridge-chip address translation turned into a BlockSpec
index map. The scalar-prefetch grid (the paged-attention pattern) lets the
DMA engine fetch each page's 8 (row, lane) slices directly:

  * grid = (n_pages, 8 slices); the page-id vector is scalar-prefetched,
  * the storage BlockSpec's index_map computes — per grid step — the paper's
    translation  ℓ = 8·slot + k,  lane = ℓ mod 9,  row = 8·group + ℓ div 9,
    skipping lane (8 − slot) mod 9 exactly as the bridge chip does,
  * each step moves one (1, 1, W) slice HBM→VMEM; slices of *different*
    lanes are independent streams — the +12.5% bank-parallelism the paper
    gains shows up here as 9 concurrently addressable lane streams.

One DMA per slice, no second pass, no read-modify-write: the access-count
behaviour of Solution 3 (Fig. 10a: "Inter-Wrap eliminates all extra memory
requests") is structural in this kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.layouts import GROUP_ROWS, LANES
from repro.kernels.common import use_interpret


def _coords(page, k, num_rows: int):
    """Bridge-chip translation for slice k of logical `page` (traced scalars)."""
    is_extra = page >= num_rows
    e = page - num_rows
    group = jnp.where(is_extra, e, page // GROUP_ROWS)
    slot = jnp.where(is_extra, GROUP_ROWS, page % GROUP_ROWS)
    linear = 8 * slot + k
    return GROUP_ROWS * group + linear // LANES, linear % LANES


def _copy_kernel(pages_ref, storage_ref, out_ref):
    out_ref[...] = storage_ref[...]


def _scatter_kernel(pages_ref, data_ref, storage_in_ref, storage_out_ref):
    storage_out_ref[...] = data_ref[...]


@functools.partial(jax.jit, static_argnames=("num_rows",))
def gather(storage: jax.Array, pages: jax.Array, num_rows: int) -> jax.Array:
    """(R, 9, W) pool, (n,) int32 page ids -> (n, 8W) page data."""
    n = pages.shape[0]
    W = storage.shape[2]

    def storage_index(i, k, pages_ref):
        row, lane = _coords(pages_ref[i], k, num_rows)
        return row, lane, 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n, 8),
        in_specs=[pl.BlockSpec((1, 1, W), storage_index)],
        out_specs=pl.BlockSpec((1, 1, W), lambda i, k, pages_ref: (i, k, 0)),
    )
    out = pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, 8, W), jnp.uint32),
        interpret=use_interpret(),
    )(pages.astype(jnp.int32), storage)
    return out.reshape(n, 8 * W)


@functools.partial(jax.jit, static_argnames=("num_rows",), donate_argnums=(0,))
def scatter(storage: jax.Array, pages: jax.Array, data: jax.Array,
            num_rows: int) -> jax.Array:
    """Write (n, 8W) pages into the pool in place (aliased output)."""
    n = pages.shape[0]
    W = storage.shape[2]

    def storage_index(i, k, pages_ref):
        row, lane = _coords(pages_ref[i], k, num_rows)
        return row, lane, 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n, 8),
        in_specs=[pl.BlockSpec((1, 1, W), lambda i, k, pages_ref: (i, k, 0)),
                  pl.BlockSpec(storage.shape,
                               lambda i, k, pages_ref: (0, 0, 0))],
        out_specs=pl.BlockSpec((1, 1, W), storage_index),
    )
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(storage.shape, jnp.uint32),
        input_output_aliases={2: 0},  # operand 2 (storage) -> output, in place
        interpret=use_interpret(),
    )(pages.astype(jnp.int32),
      data.astype(jnp.uint32).reshape(n, 8, W), storage)

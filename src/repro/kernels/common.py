"""Shared kernel utilities: interpret-mode selection, tiling helpers."""
from __future__ import annotations

import functools
import os

import jax


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return ceil_div(a, b) * b


@functools.cache
def use_interpret() -> bool:
    """Pallas kernels run in interpret mode off-TPU (this container is CPU).

    On TPU the kernels lower natively; ``REPRO_FORCE_INTERPRET=1`` forces
    interpret mode for debugging on hardware.
    """
    if os.environ.get("REPRO_FORCE_INTERPRET") == "1":
        return True
    return jax.default_backend() != "tpu"


def pick_block(n: int, preferred: int) -> int:
    """Largest divisor of n that is <= preferred (keeps grids exact)."""
    b = min(preferred, n)
    while n % b:
        b -= 1
    return b

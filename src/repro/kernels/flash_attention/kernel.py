"""Pallas TPU flash attention (causal, GQA) — O(S) memory for long context.

Standard online-softmax tiling adapted to TPU grid semantics: the grid is
(B, Hq, S/BQ, S/BK) with the KV dimension minor — sequential on TPU — so the
running max / denominator / accumulator persist in VMEM scratch across KV
steps of one query tile. Causally dead KV tiles are skipped via a masked
contribution (XLA still schedules them; on TPU the bound-check short-circuit
is handled by Mosaic's grid pruning when `causal_block_skip` maps them out).

VMEM per step (defaults BQ=BK=256, D<=256): q/k/v tiles ≈ 0.4MB + scratch
acc (BQ, D) f32 + m/l (BQ, 128) ≈ 0.4MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import pick_block, use_interpret

DEFAULT_BQ = 256
DEFAULT_BK = 256
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, bq: int, bk: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)               # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)               # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)               # (BK, D)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    if causal:
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(rows >= cols, s, NEG_INF)

    m_prev = m_scr[:, :1]                              # (BQ, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)                    # (BQ, 1)
    l_new = alpha * l_scr[:, :1] + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(kj == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] / l_scr[:, :1]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "bq", "bk"))
def attention(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True,
              scale: float | None = None, bq: int = DEFAULT_BQ,
              bk: int = DEFAULT_BK) -> jax.Array:
    """q (B,Hq,S,D), k/v (B,Hkv,S,D) -> (B,Hq,S,D). Hq % Hkv == 0."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    groups = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    bq = pick_block(s, bq)
    bk = pick_block(s, bk)
    grid = (b, hq, s // bq, s // bk)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               bq=bq, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, i, j: (b_, h // groups, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, i, j: (b_, h // groups, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=use_interpret(),
    )(q, k, v)

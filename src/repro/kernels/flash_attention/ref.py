"""Pure-jnp oracle for flash attention (causal, GQA-aware)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention(q: jax.Array, k: jax.Array, v: jax.Array,
              causal: bool = True, scale: float | None = None) -> jax.Array:
    """Reference attention.

    q: (B, Hq, S, D); k, v: (B, Hkv, S, D) with Hq % Hkv == 0.
    Returns (B, Hq, S, D) in q.dtype; softmax math in f32.
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    groups = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    kx = jnp.repeat(k, groups, axis=1)
    vx = jnp.repeat(v, groups, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kx.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vx.astype(jnp.float32))
    return out.astype(q.dtype)

"""Public entry point for flash attention with kernel/ref dispatch."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention import kernel, ref


def attention(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True,
              scale: float | None = None, use_kernel: bool = True) -> jax.Array:
    if use_kernel:
        return kernel.attention(q, k, v, causal=causal, scale=scale)
    return ref.attention(q, k, v, causal=causal, scale=scale)

"""Pure-jnp oracle for the fused SECDED-decode + matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import secded


def protect(a: jax.Array) -> tuple[jax.Array, jax.Array]:
    """bf16 (M, K) weights -> (bits (M, K//2) uint32, codes (M, K//16))."""
    m, k = a.shape
    bits = jax.lax.bitcast_convert_type(
        a.reshape(m, k // 2, 2), jnp.uint32)
    return bits, secded.encode_block(bits)


def unprotect(bits: jax.Array) -> jax.Array:
    """(M, K//2) uint32 -> bf16 (M, K)."""
    m, kw = bits.shape
    halves = jax.lax.bitcast_convert_type(bits, jnp.bfloat16)  # (M, K//2, 2)
    return halves.reshape(m, kw * 2)


def ecc_matmul(a_bits: jax.Array, a_codes: jax.Array, b: jax.Array
               ) -> jax.Array:
    """Decode-and-correct A, then A @ B. Returns f32 (M, N)."""
    fixed, _, _ = secded.decode_block(a_bits, a_codes)
    a = unprotect(fixed)
    return jnp.dot(a, b, preferred_element_type=jnp.float32)

"""Pallas TPU kernel: SECDED decode-on-load fused into a matmul (beyond-paper).

The paper's SECDED check rides along with every DRAM burst for free in
hardware. In software, protecting weights with a *separate* decode pass
doubles their HBM traffic (read for decode + read for use). This kernel
restores the paper's economics on TPU: the A operand is fetched HBM→VMEM
once per (i, k) tile, corrected in-register on the VPU, bitcast to bf16 and
fed straight to the MXU — so serving with SECDED-protected weights costs
only the +12.5% code-lane bytes, not 2× weight traffic.

Grid (M/BM, N/BN, K/BK), K minor (sequential on TPU): the f32 accumulator
lives in the revisited output block; `pl.when(k == 0)` zero-init. Default
tiles (256, 256, 512): VMEM = A bits 256×256×4 + codes + B 512×256×2 +
out 256×256×4 ≈ 0.8MB; MXU dims all 128-multiples.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import pick_block, use_interpret
from repro.kernels.secded.kernel import (_encode_beats, _syndrome_action,
                                         _unpack4)

DEFAULT_BM, DEFAULT_BN, DEFAULT_BK = 256, 256, 512


def _decode_tile(bits: jax.Array, packed_codes: jax.Array) -> jax.Array:
    """(BM, BK/2) uint32 + (BM, BK/16) codes -> corrected bf16 (BM, BK)."""
    bm, kw = bits.shape
    pairs = bits.reshape(bm, kw // 2, 2)
    lo, hi = pairs[..., 0], pairs[..., 1]
    stored = _unpack4(packed_codes, lo.shape[1])
    syndrome = (_encode_beats(lo, hi) ^ stored) & jnp.uint32(0xFF)
    action = _syndrome_action(syndrome)
    is_data = (action >= 0) & (action < 64)
    bit = jnp.where(action >= 0, action, 0).astype(jnp.uint32)
    lo = lo ^ jnp.where(is_data & (bit < 32), jnp.uint32(1) << (bit & 31), 0)
    hi = hi ^ jnp.where(is_data & (bit >= 32), jnp.uint32(1) << (bit & 31), 0)
    fixed = jnp.stack([lo, hi], axis=-1).reshape(bm, kw)
    halves = jax.lax.bitcast_convert_type(fixed, jnp.bfloat16)  # (BM, kw, 2)
    return halves.reshape(bm, kw * 2)


def _ecc_matmul_kernel(a_bits_ref, a_codes_ref, b_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = _decode_tile(a_bits_ref[...], a_codes_ref[...])
    o_ref[...] += jnp.dot(a, b_ref[...],
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def ecc_matmul(a_bits: jax.Array, a_codes: jax.Array, b: jax.Array,
               bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
               bk: int = DEFAULT_BK) -> jax.Array:
    """Corrected-A matmul: (M,K) bf16 A (as bits+codes) @ (K,N) bf16 -> f32."""
    m, kw = a_bits.shape
    k2, n = b.shape
    if k2 != kw * 2:
        raise ValueError(f"K mismatch: bits {a_bits.shape} vs b {b.shape}")
    bm = pick_block(m, bm)
    bn = pick_block(n, bn)
    bk = pick_block(k2, bk)
    grid = (m // bm, n // bn, k2 // bk)
    return pl.pallas_call(
        _ecc_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk // 2), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bk // 16), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=use_interpret(),
    )(a_bits, a_codes, b)

"""Public entry points for the fused ECC matmul with kernel/ref dispatch."""
from __future__ import annotations

import jax

from repro.kernels.ecc_matmul import kernel, ref

protect = ref.protect
unprotect = ref.unprotect


def ecc_matmul(a_bits: jax.Array, a_codes: jax.Array, b: jax.Array,
               use_kernel: bool = True) -> jax.Array:
    if use_kernel:
        return kernel.ecc_matmul(a_bits, a_codes, b)
    return ref.ecc_matmul(a_bits, a_codes, b)

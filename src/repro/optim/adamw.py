"""AdamW with ZeRO-style sharding hooks and optional gradient compression.

Functional: ``init -> state``, ``update(grads, state, params) -> (updates,
state)``. Moments are stored in f32 regardless of param dtype. Under the
production mesh the moments inherit the parameters' (FSDP×TP) shardings —
that *is* ZeRO-3 — and the trainer can additionally snapshot them into a
SECDED CREAM pool (fault tolerance, DESIGN.md §2.4).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


@jax.tree_util.register_dataclass
@dataclass
class AdamWState:
    step: jax.Array
    m: Any
    v: Any


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def cosine_schedule(cfg: TrainConfig):
    def lr(step):
        warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
        prog = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        return cfg.learning_rate * warm * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return lr


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


# -- gradient compression (distributed-optimization trick) -------------------


def compress_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantisation: (q, scale)."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    return jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8), scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def maybe_compress_grads(grads, mode: str):
    """Simulate compress->(all-reduce)->decompress. With GSPMD the actual
    reduction happens inside jit; compressing before the psum halves/quarters
    the gradient all-reduce bytes — visible in the dry-run collective term."""
    if mode == "none":
        return grads
    if mode == "int8":
        def roundtrip(g):
            q, s = compress_int8(g.astype(jnp.float32))
            return decompress_int8(q, s)
        return jax.tree.map(roundtrip, grads)
    raise ValueError(mode)


def update(grads, state: AdamWState, params, cfg: TrainConfig
           ) -> tuple[Any, AdamWState]:
    """Returns (new_params, new_state)."""
    grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = cosine_schedule(cfg)(step)
    b1, b2 = cfg.beta1, cfg.beta2

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mhat = m2 / (1 - b1 ** step)
        vhat = v2 / (1 - b2 ** step)
        delta = mhat / (jnp.sqrt(vhat) + 1e-8) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)

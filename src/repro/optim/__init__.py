"""repro.optim subpackage."""

"""Docs link check: fail on dead *relative* links in README.md and docs/.

Scans markdown files for inline links/images ``[text](target)`` and
verifies that every relative target resolves to a file or directory in
the repo (``#anchor`` fragments are checked for existence of the file
part only; external ``http(s)://`` and ``mailto:`` targets are skipped).
Run from anywhere: paths resolve against the repo root (this file's
parent's parent).

Usage::

    python tools/check_links.py [FILE_OR_DIR ...]   # default: README docs/

Exit status 0 = all links resolve, 1 = dead links (each one listed).
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
#: inline markdown link/image: [text](target) — stops at the first ')',
#: good enough for the plain relative paths these docs use
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")


def _targets(md: Path):
    text = md.read_text(encoding="utf-8")
    in_code = False
    for line in text.splitlines():
        if line.strip().startswith("```"):
            in_code = not in_code
            continue
        if in_code:
            continue
        yield from LINK_RE.findall(line)


def check_file(md: Path) -> list[str]:
    dead = []
    for target in _targets(md):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        resolved = (md.parent / path).resolve()
        if not resolved.exists():
            dead.append(f"{md.relative_to(ROOT)}: dead link -> {target}")
    return dead


def main(argv: list[str]) -> int:
    roots = [Path(a) for a in argv] if argv else \
        [ROOT / "README.md", ROOT / "docs"]
    files: list[Path] = []
    for r in roots:
        if r.is_dir():
            files.extend(sorted(r.rglob("*.md")))
        elif r.exists():
            files.append(r)
        else:
            print(f"missing input {r}", file=sys.stderr)
            return 1
    dead = [d for f in files for d in check_file(f)]
    for d in dead:
        print(d, file=sys.stderr)
    print(f"# checked {len(files)} file(s): "
          + ("all links resolve" if not dead else f"{len(dead)} dead"))
    return 1 if dead else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))

"""creamtop — the CREAM-Scope terminal dashboard.

Renders the SLO verdicts + metric sections from one of three sources:

  * ``--bench BENCH_<suite>.json`` — the ``_metrics`` blob a
    ``benchmarks/run.py --profile`` run embedded into the suite file
    (plus the CREAM-Lens bank heatmap when the file also carries a
    ``--memprof`` ``_memprof`` blob);
  * ``--snapshot metrics.json`` — a raw ``repro.obs.metrics.collect()``
    dump;
  * ``--demo`` — run a tiny live CREAM-Serve workload under scrubbing
    with error injection and render the live registry/tracker (the same
    scenario as ``examples/observe_serving.py``, smaller).

Usage::

    PYTHONPATH=src python tools/creamtop.py --bench BENCH_serving.json
    PYTHONPATH=src python tools/creamtop.py --demo
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)


def _demo() -> None:
    """A tiny live serving run: scrub + injection + dashboard."""
    import jax
    import numpy as np

    from repro.core import injection
    from repro.obs import dashboard, metrics, tracing
    from repro.serve.engine import Engine, Request

    metrics.enable()
    tracing.enable()
    from benchmarks.bench_serving import CFG
    eng = Engine(CFG, max_batch=4, max_len=32, num_rows=64, secded_rows=16)
    pool = eng.pool
    rng = np.random.default_rng(0)
    storage, _ = injection.inject_flips(pool.storage, rng, n_flips=4,
                                        row_range=(0, pool.boundary))
    import dataclasses
    eng.vm.pools[eng.pool_name] = dataclasses.replace(pool, storage=storage)
    reqs = [Request(seq_id=i, prompt=list(range(1, 9)), max_new=4,
                    tier="paid" if i % 2 else "batch") for i in range(6)]
    eng.serve(reqs)
    from repro.core.monitor import ErrorMonitor
    from repro.core.scrubber import scrub
    mon = ErrorMonitor()
    new_state, stats = scrub(eng.pool)
    eng.vm.pools[eng.pool_name] = new_state
    mon.record(eng.pool_name, stats)
    jax.block_until_ready(new_state.storage)
    print(dashboard.render())


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--bench", metavar="BENCH_JSON",
                     help="BENCH_<suite>.json with an embedded _metrics blob")
    src.add_argument("--snapshot", metavar="METRICS_JSON",
                     help="a repro.obs.metrics.collect() JSON dump")
    src.add_argument("--demo", action="store_true",
                     help="run a tiny live serving demo and render it")
    args = ap.parse_args()
    if args.demo:
        _demo()
        return
    from repro.obs import dashboard
    path = args.bench or args.snapshot
    with open(path) as f:
        blob = json.load(f)
    snap = blob.get("_metrics") if args.bench else blob
    memprof = blob.get("_memprof") if args.bench else None
    if not isinstance(snap, dict) and not isinstance(memprof, dict):
        raise SystemExit(
            f"{path}: no _metrics/_memprof blob "
            "(run benchmarks/run.py --profile and/or --memprof)")
    if isinstance(snap, dict):
        print(dashboard.render(snap=snap, statuses=[]))
    if isinstance(memprof, dict):
        # CREAM-Lens bank panel: per-profile chipxbank heatmaps
        print(dashboard.render_bank_heatmap(memprof))


if __name__ == "__main__":
    main()

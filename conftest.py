import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "src"))
sys.path.insert(0, os.path.dirname(__file__))

# Expose 8 virtual host-platform devices so the sharded-pool (CREAM-Shard)
# tests exercise a real multi-device `banks` mesh on CPU. Must run before
# first jax init; a pre-set flag (CI, user) wins.
_FLAG = "--xla_force_host_platform_device_count"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + f" {_FLAG}=8").strip()
